"""Cross-process serving (serve/server.py + serve/client.py) over real
sockets.

Server and client run in one pytest process (loopback TCP, genuine frames)
— the subprocess path is exercised by ``benchmarks/serve_smoke.py`` and
``examples/remote_analytics.py``.  Covers the serving contract: the remote
session mirrors the in-process API (values, errors, provenance), admission
control crosses the wire typed (RejectedError.retry_after, DeadlineExpired),
results stream back out-of-order by request id, shutdown drains, and
concurrent independent clients share one workspace without trampling each
other.
"""

import threading
import time

import numpy as np
import pytest

from repro.core import algorithms as A
from repro.core import provenance as P
from repro.core.graph import EdgeDelta, Graph
from repro.core.table import INT, STR, Table
from repro.data.rmat import rmat_edges
from repro.serve.client import RemoteService
from repro.serve.graph_service import GraphService, Workspace
from repro.serve.policy import (AdmissionPolicy, DeadlineExpired,
                                RejectedError, SchedulerPolicy, ServiceError)
from repro.serve.server import GraphServer


def rmat_graph(scale=7, edge_factor=4, seed=0):
    s, d = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
    return Graph.from_edges(s, d)


@pytest.fixture
def served():
    """(server, client) around an inline (workers=0) service with a graph.

    Inline mode keeps scheduling deterministic — results resolve at flush,
    which the remote client drives exactly like an in-process caller.
    """
    svc = GraphService(workers=0)
    svc.workspace.put("g", rmat_graph())
    server = GraphServer(svc).start()
    client = RemoteService(port=server.port, timeout=120.0)
    yield server, client
    client.close()
    server.shutdown()


@pytest.fixture
def served_workers():
    """(server, client) around a worker-backed (streaming) service."""
    svc = GraphService(workers=2)
    svc.workspace.put("g", rmat_graph())
    server = GraphServer(svc).start()
    client = RemoteService(port=server.port, timeout=120.0)
    yield server, client
    client.close()
    server.shutdown()


# ---------------------------------------------------------------------------
# workspace / session mirroring
# ---------------------------------------------------------------------------


def test_remote_workspace_put_get_version(served):
    server, client = served
    t = Table.from_columns({"x": INT, "s": STR},
                           {"x": [3, 1], "s": ["b", "a"]})
    v = client.workspace.put("t", t)
    assert client.workspace.version("t") == v
    assert P.peek_version(t) == v          # local root bound to server token
    assert set(client.workspace.names()) == {"g", "t"}
    back = client.workspace.get("t")
    assert back.to_pydict() == t.to_pydict()
    # and the server really holds it (shared workspace, not a client echo)
    assert server.service.workspace.get("t").to_pydict() == t.to_pydict()
    with pytest.raises(KeyError):
        client.workspace.get("nope")


def test_remote_update_refused_with_clear_error(served):
    _, client = served
    with pytest.raises(ServiceError, match="wire"):
        client.workspace.update("g", lambda g: g)


def test_remote_session_isolation_and_publish(served):
    server, client = served
    sess = client.session("alice")
    t = Table.from_columns({"x": INT}, {"x": [1, 2]})
    sess.put("mine", t)
    assert sess.local_names() == ["mine"]
    # another connection with the SAME session name must not see it
    other = RemoteService(port=server.port)
    try:
        with pytest.raises(KeyError):
            other.session("alice").get("mine")
        sess.publish("mine")
        assert other.session("alice").get("mine").to_pydict() == \
            t.to_pydict()
    finally:
        other.close()


def test_execute_mirrors_in_process_values(served):
    _, client = served
    sess = client.session("s")
    remote = sess.execute({"op": "pagerank", "graph": "g",
                           "params": {"n_iter": 10}, "as": "pr"})
    local_svc = GraphService()
    local_svc.workspace.put("g", rmat_graph())
    local = local_svc.session("s").execute(
        {"op": "pagerank", "graph": "g", "params": {"n_iter": 10}})
    np.testing.assert_allclose(np.asarray(remote), np.asarray(local),
                               rtol=1e-6)
    # the "as" binding lives server-side and reads back identically
    np.testing.assert_array_equal(np.asarray(sess.get("pr")),
                                  np.asarray(remote))


def test_multi_output_op_roundtrip(served):
    _, client = served
    hub, auth = client.session("s").execute(
        {"op": "hits", "graph": "g", "params": {"n_iter": 5}})
    assert hub.shape == auth.shape
    assert [r.op for r in P.records_of(auth)] == ["algorithms.hits"]


def test_cached_and_fused_flags_cross_the_wire(served):
    _, client = served
    sess = client.session("s")
    p1 = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 0}})
    p2 = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 1}})
    client.flush()
    assert p1.result(60) is not None and p2.result(60) is not None
    assert p1.fused and p2.fused
    p3 = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 0}})
    client.flush()
    p3.result(60)
    assert p3.cached
    stats = client.stats
    assert stats["cache_hits"] >= 1 and stats["fused_requests"] >= 2


# ---------------------------------------------------------------------------
# typed errors over the wire
# ---------------------------------------------------------------------------


def test_unknown_op_raises_service_error_at_submit(served):
    _, client = served
    with pytest.raises(ServiceError, match="unknown op"):
        client.session("s").submit({"op": "frobnicate", "graph": "g"})


def test_missing_name_resolves_keyerror_like_in_process(served):
    _, client = served
    p = client.session("s").submit({"op": "pagerank", "graph": "ghost"})
    with pytest.raises(KeyError, match="ghost"):
        p.result(60)


def test_admission_rejection_carries_retry_after(served):
    server, client = served
    server.service.policy.admission.inflight_overrides["c1/greedy"] = 2
    sess = client.session("greedy")
    ok = [sess.submit({"op": "pagerank", "graph": "g",
                       "params": {"n_iter": 2}}) for _ in range(2)]
    with pytest.raises(RejectedError) as ei:
        sess.submit({"op": "pagerank", "graph": "g", "params": {"n_iter": 2}})
    assert ei.value.retry_after > 0
    client.flush()
    for p in ok:
        assert p.result(60) is not None


def test_deadline_expired_crosses_the_wire(served):
    _, client = served
    sess = client.session("s")
    p = sess.submit({"op": "pagerank", "graph": "g",
                     "params": {"n_iter": 2}, "deadline_ms": 0.0})
    time.sleep(0.01)
    client.flush()
    with pytest.raises(DeadlineExpired):
        p.result(60)


# ---------------------------------------------------------------------------
# streaming: completion order, not call order
# ---------------------------------------------------------------------------


def test_results_stream_out_of_order(served):
    _, client = served
    sess = client.session("s")
    # first submit stays queued (inline server, nothing drains it yet)...
    slow = sess.submit({"op": "pagerank", "graph": "g",
                        "params": {"n_iter": 5}})
    # ...second resolves at submit time (name error) -> its RESULT frame
    # arrives while the earlier request is still pending
    fast = sess.submit({"op": "pagerank", "graph": "missing"})
    deadline = time.monotonic() + 30
    while not fast.done and time.monotonic() < deadline:
        time.sleep(0.005)
    assert fast.done and not slow.done
    client.flush()
    assert slow.result(60) is not None
    with pytest.raises(KeyError):
        fast.result(60)


def test_worker_server_streams_without_flush(served_workers):
    _, client = served_workers
    sess = client.session("s")
    ps = [sess.submit({"op": "bfs", "graph": "g", "params": {"source": i}})
          for i in range(4)]
    for p in ps:                    # no flush: worker threads resolve
        assert p.result(120) is not None


# ---------------------------------------------------------------------------
# provenance equivalence + export round trip (acceptance criterion)
# ---------------------------------------------------------------------------


def expert_workload(service):
    """Compact §4.1-style chain: select -> to_graph -> pagerank -> table."""
    posts = Table.from_columns(
        {"src": INT, "dst": INT, "Tag": STR},
        {"src": [0, 1, 2, 3, 0, 4], "dst": [1, 2, 0, 0, 2, 1],
         "Tag": ["java", "java", "java", "python", "java", "java"]})
    service.workspace.put("posts", posts)
    sess = service.session("analyst")
    sess.execute({"op": "select", "table": "posts",
                  "params": {"col": "Tag", "op": "==", "value": "java"},
                  "as": "jp"})
    sess.execute({"op": "to_graph", "table": "jp",
                  "params": {"src_col": "src", "dst_col": "dst"}, "as": "qg"})
    sess.execute({"op": "pagerank", "graph": "qg",
                  "params": {"n_iter": 15}, "as": "pr"})
    return sess.execute({"op": "table_from_map", "graph": "qg",
                         "scores": "pr",
                         "params": {"key_name": "User",
                                    "value_name": "Scr"}})


def test_remote_equals_in_process_values_and_provenance(served):
    _, client = served
    remote = expert_workload(client)
    local = expert_workload(GraphService())
    np.testing.assert_array_equal(remote.column_np("User"),
                                  local.column_np("User"))
    np.testing.assert_allclose(remote.column_np("Scr"),
                               local.column_np("Scr"), rtol=1e-6)
    assert [r.op for r in P.records_of(remote)] == \
        [r.op for r in P.records_of(local)]


def test_export_script_of_remote_result_reexecutes(served, tmp_path):
    _, client = served
    remote = expert_workload(client)
    script = P.export_script(remote)
    path = tmp_path / "remote_export.py"
    path.write_text(script)
    ns = {}
    exec(compile(script, str(path), "exec"), ns)
    rebuilt = ns["rebuild"]()
    np.testing.assert_allclose(rebuilt.column_np("Scr"),
                               remote.column_np("Scr"), rtol=1e-6)


def test_put_back_remote_result_keeps_chain(served):
    """hits -> put the authority vector back -> table_from_map provenance."""
    _, client = served
    sess = client.session("s")
    sess.execute({"op": "hits", "graph": "g", "params": {"n_iter": 5},
                  "as": "h"})
    _, auth = sess.get("h")
    sess.put("auth", auth)
    out = sess.execute({"op": "table_from_map", "graph": "g",
                        "scores": "auth",
                        "params": {"key_name": "User",
                                   "value_name": "A"}})
    ops = [r.op for r in P.records_of(out)]
    assert "algorithms.hits" in ops and ops[-1] == "convert.table_from_map"


# ---------------------------------------------------------------------------
# concurrent independent clients
# ---------------------------------------------------------------------------


def test_two_clients_share_workspace_and_fair_share_sees_two_sessions(
        served_workers):
    server, client1 = served_workers
    client2 = RemoteService(port=server.port)
    try:
        results = {}

        def work(tag, cli):
            sess = cli.session("w")
            vals = [sess.execute({"op": "bfs", "graph": "g",
                                  "params": {"source": s}})
                    for s in ((0, 2, 4) if tag == "a" else (1, 3, 5))]
            results[tag] = vals

        t1 = threading.Thread(target=work, args=("a", client1))
        t2 = threading.Thread(target=work, args=("b", client2))
        t1.start(); t2.start(); t1.join(60); t2.join(60)
        assert len(results["a"]) == 3 and len(results["b"]) == 3
        # result() resolves before the scheduler's completion accounting
        # runs (_run_group resolves, _done charges afterwards) — wait for
        # idle so the counters are settled before reading them
        assert server.service.scheduler.wait_idle(timeout=10.0)
        # distinct principals server-side: same client session name, two
        # connection-scoped scheduler sessions
        s1 = client1.session_stats("w")
        s2 = client2.session_stats("w")
        assert s1["completed"] == 3 and s2["completed"] == 3
    finally:
        client2.close()


def test_two_client_publish_race_stays_consistent(served):
    """Concurrent puts/publishes from two connections: every write lands,
    the name->version map never goes stale (regression for the workspace
    read-modify-write race)."""
    server, client1 = served
    client2 = RemoteService(port=server.port)
    try:
        def hammer(cli, base):
            sess = cli.session("w")
            for i in range(8):
                name = f"t{base + i}"
                sess.put(name, Table.from_columns({"x": INT},
                                                  {"x": [base + i]}))
                sess.publish(name)

        t1 = threading.Thread(target=hammer, args=(client1, 100))
        t2 = threading.Thread(target=hammer, args=(client2, 200))
        t1.start(); t2.start(); t1.join(60); t2.join(60)
        names = server.service.workspace.names()
        assert {f"t{100 + i}" for i in range(8)} <= set(names)
        assert {f"t{200 + i}" for i in range(8)} <= set(names)
        for i in range(8):
            ws = server.service.workspace
            assert ws.version(f"t{100 + i}") == \
                P.version_of(ws.get(f"t{100 + i}"))
    finally:
        client2.close()


# ---------------------------------------------------------------------------
# incremental maintenance over the wire
# ---------------------------------------------------------------------------


def test_apply_delta_over_wire_retention_and_counters(served):
    """ws_apply_delta patches the server-side graph in place of a rebuild;
    a provably-unaffected cached query survives the version bump, an
    affected one warm-starts, and session_stats reports the cache counters
    over the wire."""
    server, client = served
    client.workspace.put("pg", Graph.from_edges([0, 1, 2], [1, 2, 3]))
    sess = client.session("w")
    req = {"op": "bfs", "graph": "pg", "params": {"source": 0}}
    r1 = sess.execute(req)

    v = client.workspace.apply_delta("pg", EdgeDelta.inserts([2], [1]))
    assert client.workspace.version("pg") == v     # bump visible client-side
    assert server.service.workspace.get("pg")._delta is not None  # patched
    r2 = sess.execute(dict(req))                   # back edge: retained
    np.testing.assert_array_equal(np.asarray(r2), np.asarray(r1))
    st = client.session_stats("w")
    assert st["retained"] >= 1
    assert st["cache_hits"] >= 1 and st["cache_misses"] >= 1

    client.workspace.apply_delta("pg", EdgeDelta.inserts([0], [3]))
    r3 = sess.execute(dict(req))                   # shortcut: warm recompute
    assert np.asarray(r3)[3] == 1
    assert server.service.stats["warm_starts"] >= 1
    local = Graph.from_edges([0, 1, 2, 2, 0], [1, 2, 3, 1, 3])
    np.testing.assert_array_equal(np.asarray(r3), np.asarray(A.bfs(local, 0)))


def test_apply_delta_refreshes_client_mirror(served):
    """put() keeps a local mirror; apply_delta tracks it through deltas so
    export_script root embedding stays valid after remote updates."""
    _, client = served
    g = Graph.from_edges([0, 1], [1, 2])
    client.workspace.put("mg", g)
    v = client.workspace.apply_delta("mg", EdgeDelta.inserts([2], [0]))
    mirror = client.workspace._mirror["mg"]
    assert mirror is not g and mirror.n_edges == 3
    assert P.peek_version(mirror) == v == client.workspace.version("mg")



def test_disconnect_cleans_up_sessions(served):
    server, _ = served
    extra = RemoteService(port=server.port)
    sess = extra.session("temp")
    sess.execute({"op": "pagerank", "graph": "g", "params": {"n_iter": 2}})
    key = f"{extra.conn_id}/temp"
    assert key in server.service._sessions
    extra.close()
    deadline = time.monotonic() + 10
    while key in server.service._sessions and time.monotonic() < deadline:
        time.sleep(0.02)
    assert key not in server.service._sessions


# ---------------------------------------------------------------------------
# shutdown drains
# ---------------------------------------------------------------------------


def test_graceful_shutdown_drains_pending_work():
    svc = GraphService(workers=0)          # nothing drains until shutdown
    svc.workspace.put("g", rmat_graph())
    server = GraphServer(svc).start()
    client = RemoteService(port=server.port)
    ps = [client.session("s").submit({"op": "bfs", "graph": "g",
                                      "params": {"source": s}})
          for s in range(3)]
    assert not any(p.done for p in ps)
    server.shutdown()                      # drain flushes queued requests
    for p in ps:
        assert p.result(60) is not None   # RESULT frames flushed pre-close
    client.close()


def test_protocol_version_mismatch_rejected(served):
    import socket as socketlib

    from repro.serve import wire
    server, _ = served
    raw = socketlib.create_connection(("127.0.0.1", server.port))
    try:
        wire.send_frame(raw, wire.FrameType.REQUEST, 1,
                        {"kind": "hello", "protocol": 999})
        frame = wire.read_frame(raw)
        assert frame is not None
        ftype, _, payload = frame
        assert ftype == wire.FrameType.ERROR
        assert "protocol" in payload["message"]
    finally:
        raw.close()


# ---------------------------------------------------------------------------
# observability: trace propagation + metrics over the wire
# ---------------------------------------------------------------------------


def _span_names(client, trace):
    doc = client.chrome_trace(trace=trace)
    return {e["name"] for e in doc["traceEvents"] if e["ph"] in ("X", "i")}


def test_trace_id_reaches_provenance_and_chrome_trace(served):
    _, client = served
    sess = client.session("s")
    p = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 3}})
    v = p.result(60)
    assert p.trace                        # minted client-side at submit
    # ...lands on the server-side result's provenance metadata...
    assert ("trace", p.trace) in P.records_of(v)[-1].meta
    # ...and filters the server's Chrome trace down to this request
    names = _span_names(client, p.trace)
    assert {"rpc.submit", "service.submit", "sched.execute",
            "engine.bfs"} <= names
    doc = client.chrome_trace(trace=p.trace)
    for e in doc["traceEvents"]:
        if e["ph"] in ("X", "i"):
            args = e["args"]
            assert args.get("trace") == p.trace \
                or p.trace in args.get("traces", [])


def test_fused_requests_share_engine_span_across_traces(served):
    _, client = served
    sess = client.session("s")
    p1 = sess.submit({"op": "sssp", "graph": "g", "params": {"source": 0}})
    p2 = sess.submit({"op": "sssp", "graph": "g", "params": {"source": 1}})
    client.flush()
    p1.result(60), p2.result(60)
    assert p1.fused and p2.fused and p1.trace != p2.trace
    for p in (p1, p2):
        doc = client.chrome_trace(trace=p.trace)
        exe = [e for e in doc["traceEvents"] if e["name"] == "sched.execute"]
        assert exe and exe[0]["args"]["batch"] == 2
        assert {p1.trace, p2.trace} <= set(exe[0]["args"]["traces"])


def test_cached_request_traced_as_cache_hit(served):
    _, client = served
    sess = client.session("s")
    p1 = sess.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 3}})
    client.flush()
    p1.result(60)
    p2 = sess.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 3}})
    p2.result(60)
    assert p2.cached
    names = _span_names(client, p2.trace)
    assert "service.cache_hit_submit" in names
    assert "engine.pagerank" not in names     # never reached the engine


def test_rejected_request_traced_with_reason(served):
    server, client = served
    server.service.policy.admission.inflight_overrides["c1/greedy"] = 1
    sess = client.session("greedy")
    ok = sess.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 2}})
    tid = "t-test-rejected"
    with pytest.raises(RejectedError):
        sess.submit({"op": "pagerank", "graph": "g",
                     "params": {"n_iter": 2}, "trace": tid})
    doc = client.chrome_trace(trace=tid)
    rej = [e for e in doc["traceEvents"] if e["name"] == "sched.reject"]
    assert rej and rej[0]["args"]["reason"] == "quota"
    assert rej[0]["args"]["retry_after"] > 0
    client.flush()
    ok.result(60)


def test_deadline_expired_request_traced(served):
    _, client = served
    sess = client.session("s")
    p = sess.submit({"op": "pagerank", "graph": "g",
                     "params": {"n_iter": 2}, "deadline_ms": 0.0,
                     "trace": "t-test-expired"})
    time.sleep(0.01)
    client.flush()
    with pytest.raises(DeadlineExpired):
        p.result(60)
    names = _span_names(client, "t-test-expired")
    assert "sched.expired" in names
    assert "engine.pagerank" not in names


def test_metrics_snapshot_over_the_wire(served):
    _, client = served
    sess = client.session("s")
    sess.execute({"op": "bfs", "graph": "g", "params": {"source": 0}})
    snap = client.metrics()
    assert snap["service.requests"]["type"] == "counter"
    assert snap["service.requests"]["value"] >= 1
    assert snap["sched.engine_ms"]["type"] == "histogram"
    assert snap["sched.engine_ms"]["count"] >= 1
    txt = client.metrics_text()
    assert "# TYPE repro_service_requests counter" in txt


def test_chrome_trace_writes_local_file(served, tmp_path):
    import json as _json
    _, client = served
    sess = client.session("s")
    p = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 0}})
    p.result(60)
    path = tmp_path / "remote_trace.json"
    doc = client.chrome_trace(trace=p.trace, path=str(path))
    assert _json.loads(path.read_text()) == doc
    assert doc["traceEvents"]


def test_health_slo_and_bundle_over_the_wire(served, tmp_path):
    """The acceptance path: a forced-slow request leaves a flight-recorder
    exemplar that is still retrievable over the wire after the trace ring
    has wrapped, and the saved bundle renders through the report CLI."""
    import json as _json

    from repro import obs
    from repro.obs import report as report_mod

    server, client = served
    obs.reset()
    try:
        obs.SLO.set_objective("bfs", latency_ms=0.0)   # force "slow"
        sess = client.session("postmortem")
        p = sess.submit({"op": "bfs", "graph": "g", "params": {"source": 0}})
        client.flush()
        p.result(60)
        # wrap the server-side ring: the request's spans get evicted
        cap = obs.TRACER._events.maxlen
        t0 = time.perf_counter()
        for _ in range(cap + 1):
            obs.add_complete("pad", t0, t0)
        assert obs.TRACER.dropped > 0
        ring = client.chrome_trace(trace=p.trace)["traceEvents"]
        assert [e for e in ring if e["ph"] == "X"] == []

        health = client.health()
        assert health["status"] in ("ok", "degraded", "breaching")
        assert health["ops"]["bfs"]["slow"] >= 1
        assert isinstance(health["reasons"], list)

        report = client.slo_report()
        assert report["ops"]["bfs"]["n"] >= 1
        assert report["ops"]["bfs"]["objective"]["latency_ms"] == 0.0

        path = tmp_path / "bundle.json"
        bundle = client.debug_bundle(str(path), trace=p.trace)
        exs = bundle["exemplars"]["bfs"]
        assert exs[-1]["slow"] is True
        assert exs[-1]["trace"] == p.trace
        assert exs[-1]["spans"], "exemplar evidence must survive ring wrap"
        assert bundle["trace"]["metadata"]["dropped_events"] > 0
        assert _json.loads(path.read_text()) == bundle

        # the saved artifact renders through `python -m repro.obs.report`
        assert report_mod.main(["--bundle", str(path)]) == 0
        assert client.profile_report().startswith("engine profile")
    finally:
        obs.reset()
