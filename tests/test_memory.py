"""Bounded-memory serving (PR 8): byte accounting, eviction, leak soak.

Covers the memory-budget contract end to end:

* ``GraphPlan`` byte accounting per derived-array family, alias-safe, with
  transparent per-family eviction (re-derive on next touch, bit-identical);
* the service's byte-accounted cost-aware LRU result cache under
  :class:`~repro.serve.policy.MemoryPolicy` — tracked bytes never exceed the
  budget, LRU order holds, result entries evict before plan members, and a
  budgeted service answers every query bit-identically to an unbounded one
  (property-based, random submit/evict/delta sequences);
* concurrency: two workers hammered under a budget tight enough to force
  continuous eviction — no use-after-evict, no deadlock, counters exact
  (extends the PR 7 hammer-test pattern);
* leak soak: a long-lived service through many submit + ``apply_delta``
  cycles plateaus in tracked bytes, provenance-registry size and lineage
  depth (the unbounded strong-pin ring / ancestry-chain bugs this PR fixes).
"""

import gc
import threading
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import provenance as P
from repro.core.graph import EdgeDelta, Graph
from repro.core.plan import EVICTABLE_FAMILIES
from repro.data.rmat import rmat_edges
from repro.serve.graph_service import GraphService, RejectedError, Workspace
from repro.serve.policy import AdmissionPolicy, MemoryPolicy, SchedulerPolicy


def small_graph(n=32, e=160, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    return Graph.from_edges(src, dst)


def rmat_graph(scale=7, edge_factor=4, seed=0):
    s, d = rmat_edges(scale, edge_factor=edge_factor, seed=seed)
    return Graph.from_edges(s, d)


def budgeted_service(budget, graph=None, **kw):
    svc = GraphService(memory=MemoryPolicy(budget_bytes=budget), **kw)
    svc.workspace.put("g", graph if graph is not None else small_graph())
    return svc


# ---------------------------------------------------------------------------
# plan byte accounting + per-family eviction
# ---------------------------------------------------------------------------


def test_plan_nbytes_per_family_and_transparent_evict():
    g = small_graph()
    p = g.plan()
    cold = p.nbytes_by_family()
    assert cold["base"] > 0
    for fam in EVICTABLE_FAMILIES:
        assert cold[fam] == 0, f"{fam} should be cold"
    # materialize lazy members, capture values, evict, re-derive
    before = {
        "csr": tuple(np.asarray(a) for a in p.csr_out()),
        "perm": np.asarray(p.in_perm_out()),
        "oriented": tuple(np.asarray(a) for a in p.oriented()),
        "bsr": np.asarray(p.bsr()[0]),
        "und": np.asarray(p.undirected().out_edges()[0]),
    }
    warm = p.nbytes_by_family()
    for fam in ("csr", "perm", "oriented", "bsr", "undirected"):
        assert warm[fam] > 0, f"{fam} should be warm"
    assert p.evictable_bytes() > 0
    total = p.nbytes()
    assert total == sum(warm.values())
    freed = p.evict_all()
    assert freed > 0
    after_evict = p.nbytes_by_family()
    for fam in EVICTABLE_FAMILIES:
        assert after_evict[fam] == 0
    assert after_evict["base"] == cold["base"]  # base survives, by design
    # bit-identical re-derivation on next touch
    assert all(np.array_equal(a, b)
               for a, b in zip(before["csr"], p.csr_out()))
    assert np.array_equal(before["perm"], np.asarray(p.in_perm_out()))
    assert all(np.array_equal(a, b)
               for a, b in zip(before["oriented"], p.oriented()))
    assert np.array_equal(before["bsr"], np.asarray(p.bsr()[0]))
    assert np.array_equal(before["und"],
                          np.asarray(p.undirected().out_edges()[0]))


def test_base_family_is_never_evictable():
    p = small_graph().plan()
    with pytest.raises(ValueError):
        p.evict("base")
    with pytest.raises(ValueError):
        p.evict("lineage")


def test_csr_family_does_not_double_count_graph_storage():
    g = small_graph()
    p = g.plan()
    p.csr_out()
    p.csr_in()
    # csr_out()/csr_in() mostly alias the graph's own ptr/idx arrays; only
    # the trimmed ptr slices and deg_pad vectors are fresh memory
    assert p.nbytes_by_family()["csr"] < g.nbytes() // 4


def test_evict_clears_exec_pytrees_that_reference_family_arrays():
    from repro.core.engine import get_exec
    p = small_graph().plan()
    get_exec(p, "xla")
    assert p.execs
    p.evict("csr")
    assert not p.execs  # execs hold refs into plan arrays; must go too


# ---------------------------------------------------------------------------
# MemoryPolicy validation
# ---------------------------------------------------------------------------


def test_memory_policy_validation():
    with pytest.raises(ValueError):
        MemoryPolicy(budget_bytes=-1)
    with pytest.raises(ValueError):
        MemoryPolicy(max_lineage_depth=0)
    with pytest.raises(ValueError):
        MemoryPolicy(max_provenance_pins=0)
    assert SchedulerPolicy().memory.budget_bytes is None  # default: unbounded


# ---------------------------------------------------------------------------
# property-based eviction invariants (random submit/delta sequences)
# ---------------------------------------------------------------------------

_PROP_GRAPH = small_graph(n=48, e=220, seed=3)


def _apply_op(svc, sess, code, step):
    """One random workload step; returns (tag, result-or-None)."""
    op = code % 5
    if op == 0:
        return ("bfs", svc.execute(sess, {"op": "bfs", "graph": "g",
                                          "params": {"source": code % 48}}))
    if op == 1:
        return ("sssp", svc.execute(sess, {"op": "sssp", "graph": "g",
                                           "params": {"source": code % 48}}))
    if op == 2:
        return ("pagerank", svc.execute(
            sess, {"op": "pagerank", "graph": "g", "params": {"n_iter": 5}}))
    if op == 3:
        return ("cc", svc.execute(sess, {"op": "connected_components",
                                         "graph": "g", "params": {}}))
    # insert-only delta: deterministic edge derived from (code, step)
    u, v = (code * 7 + step) % 48, (code * 13 + 3 * step + 1) % 48
    svc.workspace.apply_delta("g", EdgeDelta.inserts([u], [v]))
    return ("delta", None)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 99), min_size=3, max_size=7),
       st.integers(20, 120))
def test_eviction_invariants_random_sequences(codes, budget_kb):
    """Budgeted vs unbounded differential run over a random op sequence.

    After every step: tracked bytes <= budget, and every query result is
    bit-identical to the unbounded service's (evicted members re-derive,
    evicted cache entries re-execute — transparently).
    """
    budget = budget_kb * 1024
    bud = budgeted_service(budget, graph=_PROP_GRAPH)
    unb = GraphService()
    unb.workspace.put("g", _PROP_GRAPH)
    sb, su = bud.session("s"), unb.session("s")
    for step, code in enumerate(codes):
        tag_b, out_b = _apply_op(bud, sb, code, step)
        tag_u, out_u = _apply_op(unb, su, code, step)
        assert tag_b == tag_u
        if out_b is not None:
            assert np.array_equal(np.asarray(out_b), np.asarray(out_u)), \
                f"divergence at step {step} ({tag_b})"
        assert bud.memory_stats()["tracked_bytes"] <= budget
    # accounting consistency: the running byte counter matches a recompute
    from repro.serve.graph_service import _value_nbytes
    with bud._lock:
        recomputed = sum(_value_nbytes(v) for v in bud._cache.values())
        assert bud._cache_bytes == recomputed
        assert set(bud._cache_cost) == set(bud._cache)


def test_result_cache_evicts_before_plan_members():
    g = rmat_graph()          # big enough that plan families carry weight
    svc = GraphService()
    svc.workspace.put("g", g)
    s = svc.session("s")
    # cc/triangles materialize the undirected + oriented plan families
    svc.execute(s, {"op": "connected_components", "graph": "g", "params": {}})
    svc.execute(s, {"op": "triangle_count", "graph": "g", "params": {}})
    for i in range(8):
        svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": i}})
    ms = svc.memory_stats()
    assert ms["plan_evictable_bytes"] > 0 and ms["result_cache_bytes"] > 0
    # budget admits all plan members but not the whole result cache: only
    # result entries may be evicted
    svc._mem.policy = MemoryPolicy(
        budget_bytes=ms["plan_evictable_bytes"] + ms["result_cache_bytes"] // 2)
    svc._mem.maybe_evict()
    assert svc.stats["evicted_results"] > 0
    assert svc.stats["evicted_plan_families"] == 0
    assert svc.memory_stats()["tracked_bytes"] \
        <= svc._mem.policy.budget_bytes
    # budget below the plan's evictable bytes: the result cache must be
    # fully spent before any plan member goes
    svc._mem.policy = MemoryPolicy(
        budget_bytes=max(svc.memory_stats()["plan_evictable_bytes"] // 2, 1))
    svc._mem.maybe_evict()
    assert len(svc._cache) == 0
    assert svc.stats["evicted_plan_families"] > 0
    assert svc.memory_stats()["tracked_bytes"] \
        <= svc._mem.policy.budget_bytes
    # ...and the evicted members re-derive bit-identically on next touch
    out = svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": 0}})
    svc2 = GraphService()
    svc2.workspace.put("g", g)
    ref = svc2.execute(svc2.session("s"),
                       {"op": "bfs", "graph": "g", "params": {"source": 0}})
    assert np.array_equal(np.asarray(out), np.asarray(ref))


def test_lru_order_holds_under_byte_eviction():
    svc = budgeted_service(None)   # start unbounded to fill deterministically
    s = svc.session("s")
    keys = []
    for i in range(10):
        svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": i}})
    with svc._lock:
        keys = list(svc._cache)
    # touch sources 0/1 (MRU), then shrink the budget to roughly half
    svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": 0}})
    svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": 1}})
    ms = svc.memory_stats()
    svc._mem.policy = MemoryPolicy(
        budget_bytes=ms["plan_evictable_bytes"]
        + ms["result_cache_bytes"] // 2)
    svc._mem.maybe_evict()
    with svc._lock:
        survivors = list(svc._cache)
    assert survivors, "eviction should not empty the cache at this budget"
    # the survivors must be exactly the most-recently-used suffix:
    # re-touched 0/1 last, before them the newest of the original fill
    expected_order = [k for k in keys if k not in (keys[0], keys[1])] \
        + [keys[0], keys[1]]
    assert survivors == expected_order[-len(survivors):]


def test_retention_and_warm_starts_respect_budget():
    budget = 40 * 1024
    svc = budgeted_service(budget)
    s = svc.session("s")
    for i in range(12):
        svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": i}})
    # deltas drive retention / warm starts; the budget must hold throughout
    for k in range(6):
        svc.workspace.apply_delta("g", EdgeDelta.inserts([k], [(k + 9) % 32]))
        svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": k}})
        assert svc.memory_stats()["tracked_bytes"] <= budget


# ---------------------------------------------------------------------------
# concurrency: continuous eviction under two workers (PR 7 hammer pattern)
# ---------------------------------------------------------------------------


def test_stats_exact_and_no_deadlock_under_budgeted_hammer():
    g = small_graph(n=64, e=320, seed=1)
    svc = GraphService(memory=MemoryPolicy(budget_bytes=24 * 1024),
                       policy=SchedulerPolicy(
                           admission=AdmissionPolicy(max_inflight=16,
                                                     max_queue_depth=256)),
                       workers=2)
    svc.workspace.put("g", g)
    n_threads, per_thread = 2, 150
    errors, done = [], []
    done_lock = threading.Lock()

    def hammer(tid):
        sess = svc.session(f"s{tid}")
        for i in range(per_thread):
            req = {"op": "bfs", "graph": "g",
                   "params": {"source": (tid * 31 + i) % 64}}
            while True:
                try:
                    p = sess.submit(req)
                    break
                except RejectedError as e:
                    time.sleep(min(e.retry_after, 0.005))
            try:
                p.result(timeout=30.0)
                with done_lock:
                    done.append(p)
            except Exception as e:   # pragma: no cover - failure detail
                errors.append((tid, i, e))

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=120.0)
    assert not any(t.is_alive() for t in threads), \
        "hammer threads wedged: deadlock between eviction and scheduler"
    svc.close()
    assert not errors, errors[:3]
    assert len(done) == n_threads * per_thread
    st_ = svc.stats
    served = st_["cache_hits"] + st_["engine_calls"] \
        + st_["fused_requests"] - st_["fused_calls"] + st_["retained"]
    assert served >= st_["engine_calls"]
    assert st_["requests"] == len(done) + st_["rejected"]
    # continuous eviction actually happened, the budget held, and the byte
    # ledger is exact (no use-after-evict would leave it consistent)
    assert st_["evicted_results"] > 0
    assert svc.memory_stats()["tracked_bytes"] <= 24 * 1024
    from repro.serve.graph_service import _value_nbytes
    with svc._lock:
        assert svc._cache_bytes == sum(_value_nbytes(v)
                                       for v in svc._cache.values())


# ---------------------------------------------------------------------------
# leak soak: tracked bytes / provenance registry / lineage must plateau
# ---------------------------------------------------------------------------


def test_leak_soak_plateaus():
    budget = 48 * 1024
    svc = budgeted_service(budget, graph=small_graph(n=40, e=180, seed=2))
    s = svc.session("s")
    depth = svc.memory.max_lineage_depth

    def sample():
        gc.collect()
        ms = svc.memory_stats()
        with P._LOCK:
            reg = len(P._BY_VERSION)
        return ms["tracked_bytes"], reg, ms["provenance_pins"]

    cycles, mid = 300, None
    for i in range(cycles):
        u, v = (3 * i) % 40, (7 * i + 1) % 40
        svc.workspace.apply_delta("g", EdgeDelta.inserts([u], [v]))
        svc.execute(s, {"op": "bfs", "graph": "g",
                        "params": {"source": i % 40}})
        if i % 3 == 0:
            svc.execute(s, {"op": "pagerank", "graph": "g",
                            "params": {"n_iter": 3}})
        assert svc.workspace.get("g").lineage_depth() <= depth
        if i == cycles // 2:
            mid = sample()
    end = sample()
    tracked_mid, reg_mid, pins_mid = mid
    tracked_end, reg_end, pins_end = end
    assert tracked_end <= budget
    # plateau: the second half of the soak must not keep growing the
    # registry or the tracked footprint (generous 25% slack + constant)
    assert tracked_end <= tracked_mid * 1.25 + 8192, (mid, end)
    assert reg_end <= reg_mid * 1.25 + 64, (mid, end)
    assert pins_end <= P.pin_stats()["capacity"]


def test_strong_pin_ring_bounded_and_registry_cleaned():
    baseline = P.pin_stats()
    try:
        P.set_pin_capacity(32)
        tokens = []
        for i in range(200):
            # tuples refuse both attributes and weakrefs -> pinned path
            tokens.append(P.version_of((i, "pin-me")))
        stats = P.pin_stats()
        assert stats["pinned"] <= 32
        assert stats["capacity"] == 32
        with P._LOCK:
            pinned_entries = sum(
                1 for v in P._BY_VERSION.values()
                if isinstance(v, tuple) and v[0] is P._PINNED)
        # pre-fix, every evicted pin leaked its _BY_VERSION entry: 200 here
        assert pinned_entries <= 32
        # evicted tokens resolve to nothing; the youngest still resolve
        assert P.object_for_version(tokens[0]) is None
        assert P.object_for_version(tokens[-1]) == (199, "pin-me")
    finally:
        P.set_pin_capacity(max(baseline["capacity"], 1))


# ---------------------------------------------------------------------------
# telemetry: gauges + session_stats + stats surfaces
# ---------------------------------------------------------------------------


def test_memory_telemetry_surfaces():
    import repro.obs as obs
    svc = budgeted_service(64 * 1024)
    s = svc.session("s")
    svc.execute(s, {"op": "bfs", "graph": "g", "params": {"source": 1}})
    ms = svc.memory_stats()
    for k in ("tracked_bytes", "budget_bytes", "result_cache_bytes",
              "plan_bytes", "plan_evictable_bytes", "provenance_pins"):
        assert k in ms
    assert ms["budget_bytes"] == 64 * 1024
    # the same numbers ride session_stats (mem_ prefix, flat scalars)...
    ss = svc.session_stats("s")
    assert ss["mem_tracked_bytes"] == svc.memory_stats()["tracked_bytes"]
    assert all(isinstance(ss[k], (int, float))
               for k in ss if k.startswith("mem_"))
    # ...and the obs gauges the metrics RPC ships are populated
    snap = obs.REGISTRY.snapshot()
    if snap:   # obs may be disabled via env in exotic CI configs
        for gname in ("mem.tracked_bytes", "mem.result_cache_bytes",
                      "mem.plan_bytes", "mem.budget_bytes"):
            assert gname in snap, sorted(snap)[:10]
        assert snap["mem.budget_bytes"]["value"] == 64 * 1024
