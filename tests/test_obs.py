"""Observability subsystem (repro/obs): registry semantics, trace spans,
structured logging, and the engine instrumentation hooks.

Registry/tracer unit tests run against *fresh* instances so they are immune
to what other tests recorded into the module-level ``obs.REGISTRY`` /
``obs.TRACER``; the engine-integration tests use the globals (that is the
wiring under test) and scope their assertions to deltas or to spans they
can identify unambiguously.
"""

import gc
import json
import logging
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import algorithms as A
from repro.core.graph import Graph
from repro.obs.log import format_event, get_logger
from repro.obs.metrics import (COUNT_BUCKETS, Registry,
                               quantile_from_snapshot)
from repro.obs.trace import NOOP_SPAN, Tracer


def small_graph():
    src = np.array([0, 1, 2, 3, 0, 1], np.int32)
    dst = np.array([1, 2, 3, 0, 2, 3], np.int32)
    return Graph.from_edges(src, dst)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics_and_identity():
    reg = Registry()
    c = reg.counter("c")
    assert reg.counter("c") is c          # create-or-return by name
    c.inc()
    c.inc(5)
    assert c.value == 6
    g = reg.gauge("g")
    g.set(3.5)
    g.add(-1.0)
    assert g.value == 2.5


def test_kind_mismatch_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError, match="x.*Counter"):
        reg.histogram("x")


def test_histogram_le_bucket_edges():
    """Prometheus ``le`` semantics: a value exactly on an edge counts in
    that edge's bucket; above the last edge goes to +Inf overflow."""
    reg = Registry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 99.0):
        h.observe(v)
    snap = reg.snapshot()["h"]
    assert snap["buckets"] == [1.0, 2.0, 4.0]
    assert snap["counts"] == [2, 2, 1, 1]   # le=1: {0.5,1.0}; le=2: {1.5,2.0}
    assert snap["count"] == 6
    assert snap["sum"] == pytest.approx(108.0)


def test_histogram_quantile_interpolates():
    reg = Registry()
    h = reg.histogram("h", buckets=(10.0, 20.0, 40.0))
    for _ in range(100):
        h.observe(15.0)                   # all mass in the (10, 20] bucket
    p50 = h.quantile(0.5)
    assert 10.0 <= p50 <= 20.0
    assert h.quantile(0.0) is not None
    assert reg.histogram("empty").quantile(0.5) is None
    # remote consumers compute the same quantile from the snapshot
    assert quantile_from_snapshot(reg.snapshot()["h"], 0.5) == \
        pytest.approx(p50)


def test_snapshot_isolation():
    reg = Registry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(3)
    h.observe(1.0)
    snap = reg.snapshot()
    c.inc(100)
    h.observe(2.0)
    assert snap["c"]["value"] == 3
    assert snap["h"]["count"] == 1


def test_prometheus_exposition():
    reg = Registry()
    reg.counter("service.requests").inc(7)
    h = reg.histogram("sched.engine_ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    txt = reg.to_prometheus()
    assert "# TYPE repro_service_requests counter" in txt
    assert "repro_service_requests 7" in txt
    # bucket counts are cumulative and end at +Inf
    assert 'repro_sched_engine_ms_bucket{le="1"} 1' in txt
    assert 'repro_sched_engine_ms_bucket{le="10"} 2' in txt
    assert 'repro_sched_engine_ms_bucket{le="+Inf"} 2' in txt
    assert "repro_sched_engine_ms_count 2" in txt


def test_reset_zeroes_but_keeps_instruments():
    reg = Registry()
    c = reg.counter("c")
    c.inc(9)
    reg.reset()
    assert c.value == 0
    assert reg.counter("c") is c          # module-global refs stay valid
    c.inc()
    assert c.value == 1


def test_disabled_mode_is_allocation_free():
    reg = Registry(enabled=False)
    tr = Tracer(enabled=False)
    c = reg.counter("c")
    h = reg.histogram("h")

    assert tr.span("s") is NOOP_SPAN      # shared no-op singleton

    def burn():
        for _ in range(1000):
            c.inc()
            h.observe(3.0)
            s = tr.span("s")
            s.finish()
            tr.instant("i")

    burn()                                # warm any lazy caches
    gc.collect()
    before = sys.getallocatedblocks()
    burn()
    gc.collect()
    after = sys.getallocatedblocks()
    assert after - before < 50            # net-zero: nothing retained
    assert c.value == 0
    assert len(tr) == 0


def test_disabled_updates_do_not_count():
    reg = Registry(enabled=True)
    c = reg.counter("c")
    c.inc()
    reg.disable()
    c.inc(100)
    reg.enable()
    c.inc()
    assert c.value == 2


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_nesting_inherits_trace_and_parent():
    tr = Tracer()
    tid = tr.new_trace_id()
    with tr.span("outer", trace=tid) as outer:
        with tr.span("inner") as inner:
            assert inner.trace == tid
            assert inner.parent_id == outer.span_id
    doc = tr.export_chrome_trace(trace=tid)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert names == {"outer", "inner"}


def test_export_filters_by_trace_and_membership():
    tr = Tracer()
    t1, t2 = tr.new_trace_id(), tr.new_trace_id()
    tr.span("a", trace=t1).finish()
    tr.span("b", trace=t2).finish()
    # a fused batch span belongs to every member's trace via ``traces``
    tr.span("fused", trace=t1, traces=[t1, t2]).finish()
    names1 = {e["name"] for e in
              tr.export_chrome_trace(trace=t1)["traceEvents"]
              if e["ph"] == "X"}
    names2 = {e["name"] for e in
              tr.export_chrome_trace(trace=t2)["traceEvents"]
              if e["ph"] == "X"}
    assert names1 == {"a", "fused"}
    assert names2 == {"b", "fused"}


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("work", foo=1):
        tr.instant("marker")
    path = tmp_path / "trace.json"
    doc = tr.export_chrome_trace(str(path))
    # the on-disk file is the same JSON document
    assert json.loads(path.read_text()) == doc
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    i = [e for e in evs if e["ph"] == "i"]
    m = [e for e in evs if e["ph"] == "M"]
    assert len(x) == 1 and len(i) == 1 and len(m) == 1
    assert x[0]["name"] == "work" and x[0]["dur"] >= 0
    assert x[0]["args"]["foo"] == 1
    for e in x + i:
        assert isinstance(e["ts"], float) and isinstance(e["tid"], int)
    assert m[0]["name"] == "thread_name"


def test_add_complete_records_retroactively():
    tr = Tracer()
    t0 = time.perf_counter()
    t1 = t0 + 0.005
    tr.add_complete("queued", t0, t1, trace="tx", op="bfs")
    ev = tr.export_chrome_trace(trace="tx")["traceEvents"][0]
    assert ev["name"] == "queued"
    assert ev["dur"] == pytest.approx(5000.0, rel=0.01)   # µs


def test_span_exit_records_error_name():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    ev = tr.export_chrome_trace()["traceEvents"][0]
    assert ev["args"]["error"] == "ValueError"


def test_ring_buffer_bounds_memory():
    tr = Tracer(capacity=8)
    for i in range(100):
        tr.span(f"s{i}").finish()
    assert len(tr) == 8


def test_cross_thread_spans_land_on_distinct_rows():
    tr = Tracer()

    def work():
        tr.span("worker-span").finish()

    t = threading.Thread(target=work)
    t.start()
    t.join()
    tr.span("main-span").finish()
    doc = tr.export_chrome_trace()
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert len(tids) == 2


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------


def test_format_event_sorts_and_quotes():
    assert format_event("ev", {}) == "ev"
    assert format_event("ev", {"b": 2, "a": "x"}) == "ev a='x' b=2"


def test_struct_logger_emits_through_repro_hierarchy():
    records = []

    class Capture(logging.Handler):
        def emit(self, record):
            records.append(record)

    lg = get_logger("repro.obs_test")
    root = logging.getLogger("repro")
    h = Capture()
    old = root.level
    root.addHandler(h)
    root.setLevel(logging.INFO)
    try:
        lg.info("incremental_fallback", op="bfs", session="s1")
        lg.debug("hidden")                # below level: not emitted
    finally:
        root.removeHandler(h)
        root.setLevel(old)
    msgs = [r.getMessage() for r in records]
    assert "incremental_fallback op='bfs' session='s1'" in msgs
    assert "hidden" not in msgs


def test_get_logger_prefixes_foreign_names():
    assert get_logger("elsewhere").stdlib.name == "repro.elsewhere"
    assert get_logger("repro.core.graph").stdlib.name == "repro.core.graph"


# ---------------------------------------------------------------------------
# engine integration: frontier rounds, tol iterations
# ---------------------------------------------------------------------------


def test_frontier_fixpoint_emits_round_spans_and_metrics():
    g = small_graph()
    rounds_before = obs.counter("engine.frontier.rounds").value
    hist = obs.histogram("engine.frontier.frontier_size",
                         buckets=COUNT_BUCKETS)
    n_before = hist.count
    tid = obs.new_trace_id()
    with obs.span("test.frontier_probe", trace=tid):
        levels = A.bfs(g, 0, backend="frontier")
    assert np.asarray(levels).tolist() == [0, 1, 1, 2]
    assert obs.counter("engine.frontier.rounds").value > rounds_before
    assert hist.count > n_before
    doc = obs.export_chrome_trace(trace=tid)
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"]
    assert "engine.frontier_fixpoint" in names
    rounds = [e for e in doc["traceEvents"]
              if e["name"] == "engine.frontier.round"]
    assert rounds, "per-round spans missing from the trace"
    assert all("frontier" in e["args"] for e in rounds)
    # first round explores from the single source
    assert rounds[0]["args"]["frontier"] == 1


def test_pagerank_tol_iterations_observed_cold_vs_warm():
    g = small_graph()
    cold = obs.histogram("engine.fixpoint.tol_iters.pagerank",
                         buckets=COUNT_BUCKETS)
    warm = obs.histogram("engine.fixpoint.tol_iters.pagerank_warm",
                         buckets=COUNT_BUCKETS)
    c0, w0 = cold.count, warm.count
    pr = A.pagerank(g, tol=1e-6)
    assert cold.count == c0 + 1 and warm.count == w0
    A.pagerank(g, tol=1e-6, init=pr)
    assert warm.count == w0 + 1


def test_dump_metrics_formats():
    obs.counter("test.dump_probe").inc()
    snap = obs.dump_metrics()
    assert snap["test.dump_probe"]["type"] == "counter"
    assert "# TYPE" in obs.dump_metrics("prom")
    with pytest.raises(ValueError):
        obs.dump_metrics("xml")
