"""Observability subsystem (repro/obs): registry semantics, trace spans,
structured logging, and the engine instrumentation hooks.

Registry/tracer unit tests run against *fresh* instances so they are immune
to what other tests recorded into the module-level ``obs.REGISTRY`` /
``obs.TRACER``; the engine-integration tests use the globals (that is the
wiring under test) and scope their assertions to deltas or to spans they
can identify unambiguously.
"""

import gc
import json
import logging
import sys
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import algorithms as A
from repro.core.graph import Graph
from repro.obs.log import format_event, get_logger
from repro.obs.metrics import (COUNT_BUCKETS, Registry,
                               quantile_from_snapshot)
from repro.obs.trace import NOOP_SPAN, Tracer


def small_graph():
    src = np.array([0, 1, 2, 3, 0, 1], np.int32)
    dst = np.array([1, 2, 3, 0, 2, 3], np.int32)
    return Graph.from_edges(src, dst)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_counter_gauge_basics_and_identity():
    reg = Registry()
    c = reg.counter("c")
    assert reg.counter("c") is c          # create-or-return by name
    c.inc()
    c.inc(5)
    assert c.value == 6
    g = reg.gauge("g")
    g.set(3.5)
    g.add(-1.0)
    assert g.value == 2.5


def test_kind_mismatch_raises():
    reg = Registry()
    reg.counter("x")
    with pytest.raises(TypeError, match="x.*Counter"):
        reg.histogram("x")


def test_histogram_le_bucket_edges():
    """Prometheus ``le`` semantics: a value exactly on an edge counts in
    that edge's bucket; above the last edge goes to +Inf overflow."""
    reg = Registry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 1.5, 2.0, 4.0, 99.0):
        h.observe(v)
    snap = reg.snapshot()["h"]
    assert snap["buckets"] == [1.0, 2.0, 4.0]
    assert snap["counts"] == [2, 2, 1, 1]   # le=1: {0.5,1.0}; le=2: {1.5,2.0}
    assert snap["count"] == 6
    assert snap["sum"] == pytest.approx(108.0)


def test_histogram_quantile_interpolates():
    reg = Registry()
    h = reg.histogram("h", buckets=(10.0, 20.0, 40.0))
    for _ in range(100):
        h.observe(15.0)                   # all mass in the (10, 20] bucket
    p50 = h.quantile(0.5)
    assert 10.0 <= p50 <= 20.0
    assert h.quantile(0.0) is not None
    assert reg.histogram("empty").quantile(0.5) is None
    # remote consumers compute the same quantile from the snapshot
    assert quantile_from_snapshot(reg.snapshot()["h"], 0.5) == \
        pytest.approx(p50)


def test_snapshot_isolation():
    reg = Registry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(3)
    h.observe(1.0)
    snap = reg.snapshot()
    c.inc(100)
    h.observe(2.0)
    assert snap["c"]["value"] == 3
    assert snap["h"]["count"] == 1


def test_prometheus_exposition():
    reg = Registry()
    reg.counter("service.requests").inc(7)
    h = reg.histogram("sched.engine_ms", buckets=(1.0, 10.0))
    h.observe(0.5)
    h.observe(5.0)
    txt = reg.to_prometheus()
    assert "# TYPE repro_service_requests counter" in txt
    assert "repro_service_requests 7" in txt
    # bucket counts are cumulative and end at +Inf
    assert 'repro_sched_engine_ms_bucket{le="1"} 1' in txt
    assert 'repro_sched_engine_ms_bucket{le="10"} 2' in txt
    assert 'repro_sched_engine_ms_bucket{le="+Inf"} 2' in txt
    assert "repro_sched_engine_ms_count 2" in txt


def test_reset_zeroes_but_keeps_instruments():
    reg = Registry()
    c = reg.counter("c")
    c.inc(9)
    reg.reset()
    assert c.value == 0
    assert reg.counter("c") is c          # module-global refs stay valid
    c.inc()
    assert c.value == 1


def test_disabled_mode_is_allocation_free():
    reg = Registry(enabled=False)
    tr = Tracer(enabled=False)
    c = reg.counter("c")
    h = reg.histogram("h")

    assert tr.span("s") is NOOP_SPAN      # shared no-op singleton

    def burn():
        for _ in range(1000):
            c.inc()
            h.observe(3.0)
            s = tr.span("s")
            s.finish()
            tr.instant("i")

    burn()                                # warm any lazy caches
    gc.collect()
    before = sys.getallocatedblocks()
    burn()
    gc.collect()
    after = sys.getallocatedblocks()
    assert after - before < 50            # net-zero: nothing retained
    assert c.value == 0
    assert len(tr) == 0


def test_disabled_updates_do_not_count():
    reg = Registry(enabled=True)
    c = reg.counter("c")
    c.inc()
    reg.disable()
    c.inc(100)
    reg.enable()
    c.inc()
    assert c.value == 2


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


def test_span_nesting_inherits_trace_and_parent():
    tr = Tracer()
    tid = tr.new_trace_id()
    with tr.span("outer", trace=tid) as outer:
        with tr.span("inner") as inner:
            assert inner.trace == tid
            assert inner.parent_id == outer.span_id
    doc = tr.export_chrome_trace(trace=tid)
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert names == {"outer", "inner"}


def test_export_filters_by_trace_and_membership():
    tr = Tracer()
    t1, t2 = tr.new_trace_id(), tr.new_trace_id()
    tr.span("a", trace=t1).finish()
    tr.span("b", trace=t2).finish()
    # a fused batch span belongs to every member's trace via ``traces``
    tr.span("fused", trace=t1, traces=[t1, t2]).finish()
    names1 = {e["name"] for e in
              tr.export_chrome_trace(trace=t1)["traceEvents"]
              if e["ph"] == "X"}
    names2 = {e["name"] for e in
              tr.export_chrome_trace(trace=t2)["traceEvents"]
              if e["ph"] == "X"}
    assert names1 == {"a", "fused"}
    assert names2 == {"b", "fused"}


def test_chrome_trace_schema(tmp_path):
    tr = Tracer()
    with tr.span("work", foo=1):
        tr.instant("marker")
    path = tmp_path / "trace.json"
    doc = tr.export_chrome_trace(str(path))
    # the on-disk file is the same JSON document
    assert json.loads(path.read_text()) == doc
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    x = [e for e in evs if e["ph"] == "X"]
    i = [e for e in evs if e["ph"] == "i"]
    m = [e for e in evs if e["ph"] == "M"]
    assert len(x) == 1 and len(i) == 1 and len(m) == 1
    assert x[0]["name"] == "work" and x[0]["dur"] >= 0
    assert x[0]["args"]["foo"] == 1
    for e in x + i:
        assert isinstance(e["ts"], float) and isinstance(e["tid"], int)
    assert m[0]["name"] == "thread_name"


def test_add_complete_records_retroactively():
    tr = Tracer()
    t0 = time.perf_counter()
    t1 = t0 + 0.005
    tr.add_complete("queued", t0, t1, trace="tx", op="bfs")
    ev = tr.export_chrome_trace(trace="tx")["traceEvents"][0]
    assert ev["name"] == "queued"
    assert ev["dur"] == pytest.approx(5000.0, rel=0.01)   # µs


def test_span_exit_records_error_name():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    ev = tr.export_chrome_trace()["traceEvents"][0]
    assert ev["args"]["error"] == "ValueError"


def test_ring_buffer_bounds_memory():
    tr = Tracer(capacity=8)
    for i in range(100):
        tr.span(f"s{i}").finish()
    assert len(tr) == 8


def test_cross_thread_spans_land_on_distinct_rows():
    tr = Tracer()

    def work():
        tr.span("worker-span").finish()

    t = threading.Thread(target=work)
    t.start()
    t.join()
    tr.span("main-span").finish()
    doc = tr.export_chrome_trace()
    tids = {e["tid"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert len(tids) == 2


# ---------------------------------------------------------------------------
# structured logging
# ---------------------------------------------------------------------------


def test_format_event_sorts_and_quotes():
    assert format_event("ev", {}) == "ev"
    assert format_event("ev", {"b": 2, "a": "x"}) == "ev a='x' b=2"


def test_struct_logger_emits_through_repro_hierarchy():
    records = []

    class Capture(logging.Handler):
        def emit(self, record):
            records.append(record)

    lg = get_logger("repro.obs_test")
    root = logging.getLogger("repro")
    h = Capture()
    old = root.level
    root.addHandler(h)
    root.setLevel(logging.INFO)
    try:
        lg.info("incremental_fallback", op="bfs", session="s1")
        lg.debug("hidden")                # below level: not emitted
    finally:
        root.removeHandler(h)
        root.setLevel(old)
    msgs = [r.getMessage() for r in records]
    assert "incremental_fallback op='bfs' session='s1'" in msgs
    assert "hidden" not in msgs


def test_get_logger_prefixes_foreign_names():
    assert get_logger("elsewhere").stdlib.name == "repro.elsewhere"
    assert get_logger("repro.core.graph").stdlib.name == "repro.core.graph"


# ---------------------------------------------------------------------------
# engine integration: frontier rounds, tol iterations
# ---------------------------------------------------------------------------


def test_frontier_fixpoint_emits_round_spans_and_metrics():
    g = small_graph()
    rounds_before = obs.counter("engine.frontier.rounds").value
    hist = obs.histogram("engine.frontier.frontier_size",
                         buckets=COUNT_BUCKETS)
    n_before = hist.count
    tid = obs.new_trace_id()
    with obs.span("test.frontier_probe", trace=tid):
        levels = A.bfs(g, 0, backend="frontier")
    assert np.asarray(levels).tolist() == [0, 1, 1, 2]
    assert obs.counter("engine.frontier.rounds").value > rounds_before
    assert hist.count > n_before
    doc = obs.export_chrome_trace(trace=tid)
    names = [e["name"] for e in doc["traceEvents"] if e["ph"] == "X"]
    assert "engine.frontier_fixpoint" in names
    rounds = [e for e in doc["traceEvents"]
              if e["name"] == "engine.frontier.round"]
    assert rounds, "per-round spans missing from the trace"
    assert all("frontier" in e["args"] for e in rounds)
    # first round explores from the single source
    assert rounds[0]["args"]["frontier"] == 1


def test_pagerank_tol_iterations_observed_cold_vs_warm():
    g = small_graph()
    cold = obs.histogram("engine.fixpoint.tol_iters.pagerank",
                         buckets=COUNT_BUCKETS)
    warm = obs.histogram("engine.fixpoint.tol_iters.pagerank_warm",
                         buckets=COUNT_BUCKETS)
    c0, w0 = cold.count, warm.count
    pr = A.pagerank(g, tol=1e-6)
    assert cold.count == c0 + 1 and warm.count == w0
    A.pagerank(g, tol=1e-6, init=pr)
    assert warm.count == w0 + 1


def test_dump_metrics_formats():
    obs.counter("test.dump_probe").inc()
    snap = obs.dump_metrics()
    assert snap["test.dump_probe"]["type"] == "counter"
    assert "# TYPE" in obs.dump_metrics("prom")
    with pytest.raises(ValueError):
        obs.dump_metrics("xml")


# ---------------------------------------------------------------------------
# quantile edge cases (PR 10: None instead of fabricated values)
# ---------------------------------------------------------------------------


def test_quantile_none_on_degenerate_mass():
    reg = Registry()
    first = reg.histogram("deg.first", buckets=(10.0, 20.0))
    first.observe(5.0)                 # all mass in the zero-anchored bucket
    assert first.quantile(0.5) is None
    over = reg.histogram("deg.over", buckets=(10.0, 20.0))
    over.observe(99.0)                 # all mass in the +Inf overflow
    assert over.quantile(0.5) is None
    assert over.quantile(0.01) is None


def test_quantile_none_on_single_edge_histogram():
    reg = Registry()
    h = reg.histogram("deg.single", buckets=(10.0,))
    assert h.quantile(0.5) is None     # empty
    h.observe(5.0)                     # below the only edge -> first bucket
    assert h.quantile(0.5) is None
    h2 = reg.histogram("deg.single2", buckets=(10.0,))
    h2.observe(50.0)                   # above the only edge -> overflow
    assert h2.quantile(0.5) is None


def test_quantile_recovers_once_mass_is_bracketed():
    reg = Registry()
    h = reg.histogram("deg.mixed", buckets=(10.0, 20.0, 40.0))
    h.observe(5.0)
    assert h.quantile(0.99) is None
    h.observe(15.0)                    # now a real edge pair brackets data
    p = h.quantile(0.99)
    assert p is not None and 0.0 < p <= 20.0
    # snapshot-side helper agrees with the live instrument
    snap = reg.snapshot()
    assert quantile_from_snapshot(snap["deg.mixed"], 0.99) == p
    assert quantile_from_snapshot(snap["deg.first"], 0.5) is None \
        if "deg.first" in snap else True


# ---------------------------------------------------------------------------
# trace ring drop accounting (PR 10)
# ---------------------------------------------------------------------------


def test_ring_wrap_counts_drops():
    reg = Registry()
    tr = Tracer(capacity=8)
    tr.drop_hook = reg.counter("trace.dropped").inc
    for i in range(100):
        with tr.span(f"s{i}"):
            pass
    assert len(tr) == 8
    assert tr.dropped == 92
    st = tr.stats()
    assert st["capacity"] == 8
    assert st["buffered"] == 8
    assert st["dropped"] == 92
    assert reg.counter("trace.dropped").value == 92
    doc = tr.export_chrome_trace()
    assert doc["metadata"]["dropped_events"] == 92
    assert doc["metadata"]["capacity"] == 8
    tr.clear()
    assert tr.dropped == 0
    assert tr.stats()["dropped"] == 0


def test_global_tracer_drop_hook_is_wired():
    # obs/__init__ must route ring overflow into the trace.dropped counter
    assert obs.TRACER.drop_hook is not None


# ---------------------------------------------------------------------------
# SLO tracker: window math, hysteresis, shedding (PR 10)
# ---------------------------------------------------------------------------

from repro.obs.slo import Objective, SLOTracker  # noqa: E402


def _slo_tracker(clk, **kw):
    defaults = dict(window_s=10.0, n_buckets=5,
                    default=Objective(latency_ms=100.0, error_budget=0.1),
                    clear_ticks=2, clock=lambda: clk[0])
    defaults.update(kw)
    return SLOTracker(Registry(), **defaults)


def test_slo_burn_rate_and_window_rotation():
    clk = [0.0]
    trk = _slo_tracker(clk)
    for _ in range(5):
        trk.observe("bfs", 10.0)       # within the 100ms objective
    for _ in range(5):
        trk.observe("bfs", 500.0)      # blown
    h = trk.health()
    # bad fraction 0.5 over a 0.1 budget -> burn rate 5 -> breaching
    assert h["ops"]["bfs"]["burn_rate"] == pytest.approx(5.0)
    assert h["ops"]["bfs"]["status"] == "breaching"
    assert h["status"] == "breaching"
    assert any("bfs" in r for r in h["reasons"])
    # t=6s (bucket width 2s): old data still inside the 10s window
    clk[0] = 6.0
    trk.observe("bfs", 10.0)
    assert trk.health()["ops"]["bfs"]["n"] == 11
    # t=11s: the t=0 bucket rotated out; only the t=6 observation remains
    clk[0] = 11.0
    h = trk.health()
    assert h["ops"]["bfs"]["n"] == 1
    assert h["ops"]["bfs"]["burn_rate"] == 0.0


def test_slo_burn_across_bucket_boundary():
    clk = [0.0]
    trk = _slo_tracker(clk)
    # spread bad completions across two adjacent buckets: the window
    # aggregates them into one burn rate
    trk.observe("pr", 500.0)
    clk[0] = 2.5                       # next bucket
    trk.observe("pr", 500.0)
    trk.observe("pr", 10.0)
    trk.observe("pr", 10.0)
    h = trk.health()
    assert h["ops"]["pr"]["n"] == 4
    assert h["ops"]["pr"]["bad_fraction"] == pytest.approx(0.5)
    assert h["ops"]["pr"]["burn_rate"] == pytest.approx(5.0)


def test_slo_verdict_hysteresis():
    clk = [0.0]
    trk = _slo_tracker(clk)
    for _ in range(10):
        trk.observe("bfs", 500.0)
    assert trk.health()["ops"]["bfs"]["status"] == "breaching"  # immediate
    clk[0] = 12.0                      # slow burst fully rotated out
    trk.observe("bfs", 10.0)           # healthy traffic in the new window
    h1 = trk.health()
    assert h1["ops"]["bfs"]["burn_rate"] == 0.0       # raw data is clean...
    assert h1["ops"]["bfs"]["status"] == "breaching"  # ...verdict lags
    assert h1["status"] == "breaching"
    h2 = trk.health()                  # clear_ticks=2 -> second clean eval
    assert h2["ops"]["bfs"]["status"] == "ok"
    assert h2["status"] == "ok"


def test_slo_shedding_is_per_op_unless_combined_burn():
    clk = [0.0]
    trk = _slo_tracker(clk)
    trk.set_objective("bfs", latency_ms=1.0, error_budget=0.01)
    assert not trk.should_shed("bfs")
    for _ in range(10):
        trk.observe("bfs", 50.0)       # every bfs blown
    for _ in range(90):
        trk.observe("pagerank", 10.0)  # plenty of healthy traffic elsewhere
    clk[0] += 2.0                      # invalidate the shed cache
    assert trk.should_shed("bfs")
    # combined bad fraction is 0.1 == default budget -> burn 1.0, below
    # breach: the healthy op is NOT shed
    assert not trk.should_shed("pagerank")
    h = trk.health()
    assert h["combined"]["status"] != "breaching"
    assert h["ops"]["bfs"]["status"] == "breaching"


def test_slo_tick_folds_snapshot_deltas():
    reg = Registry()
    trk = SLOTracker(reg, window_s=10.0, n_buckets=5)
    reg.counter("service.requests").inc(5)
    assert trk.tick()["service.requests"] == 5
    reg.counter("service.requests").inc(3)
    assert trk.tick()["service.requests"] == 3       # delta, not absolute
    assert trk.health()["service"]["service.requests"] == 8
    reg.reset()
    # a registry reset between ticks reads as "no traffic", never negative
    assert trk.tick()["service.requests"] == 0


def test_slo_set_objective_partial_override():
    clk = [0.0]
    trk = _slo_tracker(clk)
    obj = trk.set_objective("bfs", latency_ms=50.0)
    assert obj.latency_ms == 50.0
    assert obj.error_budget == 0.1     # inherited from the default
    obj2 = trk.set_objective("bfs", error_budget=0.02)
    assert obj2.latency_ms == 50.0     # previous override kept
    assert obj2.error_budget == 0.02
    assert trk.objective_for("pagerank").latency_ms == 100.0


# ---------------------------------------------------------------------------
# flight recorder: exemplar capture + debug bundle (PR 10)
# ---------------------------------------------------------------------------

from repro.serve.graph_service import DeadlineExpired, GraphService  # noqa: E402
from repro.serve.policy import (AdmissionPolicy, RejectedError,  # noqa: E402
                                SchedulerPolicy)


def _flight_service():
    svc = GraphService(workers=0)
    svc.workspace.put("g", small_graph())
    return svc


def test_flight_exemplar_on_slow_completion():
    obs.reset()
    try:
        obs.SLO.set_objective("pagerank", latency_ms=0.0)  # everything slow
        svc = _flight_service()
        s = svc.session("alice")
        p = s.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 2}})
        svc.flush()
        p.result()
        exs = obs.FLIGHT.exemplars("pagerank")
        assert exs, "slow completion must capture an exemplar"
        ex = exs[-1]
        assert ex["outcome"] == "ok" and ex["slow"] is True
        assert ex["session"] == "alice"
        assert ex["latency_ms"] is not None and ex["latency_ms"] > 0
        assert ex["slo_latency_ms"] == 0.0
        assert ex["spans"], "span tree frozen at completion time"
        names = {e["name"] for e in ex["spans"]}
        assert "service.submit" in names
        assert "counters_delta" in ex
        svc.close()
    finally:
        obs.reset()


def test_flight_exemplar_on_error_and_expired():
    obs.reset()
    try:
        svc = _flight_service()
        s = svc.session("bob")
        # error path: input resolution fails at submit (bypasses scheduler)
        pe = s.submit({"op": "pagerank", "graph": "no-such-graph",
                       "params": {}})
        with pytest.raises(Exception):
            pe.result()
        exs = obs.FLIGHT.exemplars("pagerank")
        assert any(e["outcome"] == "error" and e["error"] for e in exs)
        # expired path: deadline already blown when the scheduler dequeues
        px = s.submit({"op": "pagerank", "graph": "g",
                       "params": {"n_iter": 2}, "deadline_ms": 0})
        svc.flush()
        with pytest.raises(DeadlineExpired):
            px.result()
        exs = obs.FLIGHT.exemplars("pagerank")
        assert any(e["outcome"] == "expired" for e in exs)
        # fast, healthy requests capture nothing
        before = obs.FLIGHT.stats()["exemplars"]
        pg = s.submit({"op": "pagerank", "graph": "g",
                       "params": {"n_iter": 2}})
        svc.flush()
        pg.result()
        assert obs.FLIGHT.stats()["exemplars"] == before
        svc.close()
    finally:
        obs.reset()


def _fake_done_pending(latency_ms=5.0, error=None):
    return type("P", (), {"done": True, "error": error, "trace": None,
                          "latency_ms": latency_ms, "cached": False,
                          "fused": False})()


def test_flight_store_is_bounded_per_op():
    obs.reset()
    saved = obs.FLIGHT.min_capture_interval_s
    obs.FLIGHT.min_capture_interval_s = 0.0
    try:
        cap = obs.FLIGHT.per_op_capacity
        obs.SLO.set_objective("bfs", latency_ms=0.0)
        for i in range(cap + 5):
            obs.FLIGHT.record_pending(_fake_done_pending(), op="bfs",
                                      session="s")
        assert len(obs.FLIGHT.exemplars("bfs")) == cap
    finally:
        obs.FLIGHT.min_capture_interval_s = saved
        obs.reset()


def test_flight_throttles_slow_captures_not_errors():
    obs.reset()
    try:
        obs.SLO.set_objective("bfs", latency_ms=0.0)
        # a burst of slow-but-successful completions: only the first is
        # frozen inside the min-capture interval, the rest are counted
        for _ in range(5):
            obs.FLIGHT.record_pending(_fake_done_pending(), op="bfs",
                                      session="s")
        assert len(obs.FLIGHT.exemplars("bfs")) == 1
        assert obs.FLIGHT.stats()["throttled"] == 4
        # errors are exempt from the rate limit
        for _ in range(3):
            obs.FLIGHT.record_pending(
                _fake_done_pending(error=ValueError("boom")), op="bfs",
                session="s")
        errs = [e for e in obs.FLIGHT.exemplars("bfs")
                if e["outcome"] == "error"]
        assert len(errs) == 3
        assert obs.FLIGHT.stats()["throttled"] == 4
    finally:
        obs.reset()


def test_debug_bundle_round_trip_survives_ring_wrap(tmp_path):
    obs.reset()
    try:
        obs.SLO.set_objective("bfs", latency_ms=0.0)
        svc = _flight_service()
        s = svc.session("carol")
        p = s.submit({"op": "bfs", "graph": "g", "params": {"source": 0}})
        svc.flush()
        p.result()
        ex = obs.FLIGHT.exemplars("bfs")[-1]
        assert ex["spans"] and ex["trace"]
        # wrap the ring: pad events evict every span of the bfs request
        cap = obs.TRACER._events.maxlen
        t0 = time.perf_counter()
        for _ in range(cap + 1):
            obs.add_complete("pad", t0, t0)
        assert obs.TRACER.dropped > 0
        live = obs.export_chrome_trace(trace=ex["trace"])["traceEvents"]
        assert [e for e in live if e["ph"] == "X"] == []  # ring forgot it
        assert obs.FLIGHT.exemplars("bfs")[-1]["spans"]   # recorder didn't
        # bundle: exact JSON round trip through disk
        path = tmp_path / "bundle.json"
        bundle = obs.debug_bundle(str(path), trace=ex["trace"])
        assert json.loads(path.read_text()) == bundle
        assert bundle["kind"] == "repro-debug-bundle"
        assert bundle["health"]["status"] in ("ok", "degraded", "breaching")
        assert bundle["slo"]["ops"]["bfs"]["n"] >= 1
        assert bundle["tracer"]["dropped"] > 0
        assert bundle["trace"]["metadata"]["dropped_events"] > 0
        assert bundle["exemplars"]["bfs"][-1]["spans"]
        assert bundle["config"]["obs_enabled"] is True
        # and it renders through the dashboard
        from repro.obs.report import render_bundle
        text = render_bundle(bundle)
        assert "bfs" in text
        assert "flight recorder" in text
        assert "spans=" in text
        svc.close()
    finally:
        obs.reset()


def test_slo_shed_tightens_admission():
    obs.reset()
    try:
        pol = SchedulerPolicy(admission=AdmissionPolicy(
            max_inflight=8, max_queue_depth=100,
            slo_shed=True, shed_factor=0.125))
        svc = GraphService(workers=0, policy=pol)
        svc.workspace.put("g", small_graph())
        s = svc.session("greedy")
        # no breach -> full quota: several queued submissions are fine
        for i in range(3):
            s.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 2 + i}})
        svc.flush()
        # blow the objective so pagerank starts breaching
        obs.SLO.set_objective("pagerank", latency_ms=0.0, error_budget=0.01)
        p = s.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 9}})
        svc.flush()
        p.result()
        # refresh the verdict (should_shed serves a 1s-cached health view)
        assert obs.SLO.health()["ops"]["pagerank"]["status"] == "breaching"
        # quota shrinks to max(1, 8*0.125) = 1: second in-flight rejects
        s.submit({"op": "pagerank", "graph": "g", "params": {"n_iter": 10}})
        with pytest.raises(RejectedError) as ei:
            s.submit({"op": "pagerank", "graph": "g",
                      "params": {"n_iter": 11}})
        assert "slo shedding" in str(ei.value)
        svc.flush()
        svc.close()
    finally:
        obs.reset()


# ---------------------------------------------------------------------------
# engine profiler (PR 10)
# ---------------------------------------------------------------------------


def test_profile_compile_execute_split_and_report():
    obs.reset()
    g = small_graph()
    A.pagerank(g, n_iter=3)
    A.pagerank(g, n_iter=3)            # same signature: execute only
    snap = obs.dump_metrics()
    prof = {k: v for k, v in snap.items()
            if k.startswith("engine.profile.") and v["type"] == "histogram"}
    assert prof, "profiled fixpoint must emit engine.profile.* histograms"
    total = sum(v["count"] for v in prof.values())
    assert total >= 2
    # the second identical call must not land in a compile_ms bucket again:
    # at most one compile observation per (backend, signature)
    compiles = sum(v["count"] for k, v in prof.items()
                   if k.endswith(".compile_ms"))
    executes = sum(v["count"] for k, v in prof.items()
                   if k.endswith(".execute_ms"))
    assert executes >= 1
    assert compiles <= total - 1
    rep = obs.profile_report()
    assert "engine profile" in rep
    assert "execute_ms" in rep


def test_profile_frontier_round_phases():
    obs.reset()
    rng = np.random.default_rng(3)
    src = rng.integers(0, 256, 2048).astype(np.int32)
    dst = rng.integers(0, 256, 2048).astype(np.int32)
    g = Graph.from_edges(src, dst)
    A.bfs(g, 0, backend="frontier")
    snap = obs.dump_metrics()
    rounds = snap.get("engine.frontier.rounds", {}).get("value", 0)
    assert rounds >= 1
    timed = sum(snap[k]["count"] for k in
                ("engine.profile.frontier.dense_ms",
                 "engine.profile.frontier.sparse_ms") if k in snap)
    assert timed == rounds, "every frontier round gets a timed phase"
