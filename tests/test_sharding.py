"""Sharding rules + HLO cost model unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config, SHAPES
from repro.launch import sharding as shlib
from repro.launch.hlo_cost import analyze_hlo, xla_cost_dict
from repro.models import transformer as model


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-moe-235b-a22b",
                                  "jamba-1.5-large-398b", "xlstm-350m",
                                  "whisper-small", "grok-1-314b"])
def test_param_specs_cover_every_leaf(arch):
    """Every param leaf gets a spec whose axes fit its rank and divide the
    production dims (checked symbolically on full-size shapes)."""
    cfg = get_config(arch)
    shapes = jax.eval_shape(lambda: model.init_params(
        cfg, jax.random.PRNGKey(0)))
    # production EP policy: experts shard over model only when divisible
    eap = cfg.n_experts > 0 and cfg.n_experts % 16 == 0
    rules = shlib.default_rules(_mesh11(), expert_axis_parallel=eap)
    specs = shlib.param_specs(shapes, rules)
    prod = {"data": 16, "model": 16, None: 1}

    def check(path, leaf, spec):
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for i, ax in enumerate(spec):
            dim = leaf.shape[i + leaf.ndim - len(spec)] \
                if len(spec) < leaf.ndim else leaf.shape[i]
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a is not None:
                    assert dim % prod[a] == 0, \
                        f"{jax.tree_util.keystr(path)}: {leaf.shape} vs {spec}"

    jax.tree_util.tree_map_with_path(check, shapes, specs)


def test_no_duplicate_axes_in_specs():
    for arch in ("qwen3-moe-235b-a22b", "jamba-1.5-large-398b"):
        cfg = get_config(arch)
        shapes = jax.eval_shape(lambda c=cfg: model.init_params(
            c, jax.random.PRNGKey(0)))
        rules = shlib.default_rules(_mesh11(), two_d_weights=True,
                                    expert_axis_parallel=True)
        specs = shlib.param_specs(shapes, rules)
        for spec in jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)):
            flat = [a for ax in spec
                    for a in (ax if isinstance(ax, tuple) else (ax,))
                    if a is not None]
            assert len(flat) == len(set(flat)), spec


def test_shard_is_identity_without_rules():
    x = jnp.ones((4, 4))
    assert shlib.shard(x, ("batch", None)) is x


# ---------------------------------------------------------------------------
# HLO cost model (the §Roofline measurement tool)
# ---------------------------------------------------------------------------


def _xla_flops(compiled):
    return float(xla_cost_dict(compiled)["flops"])


def test_hlo_cost_matches_xla_without_scans():
    def f(x, y):
        return jnp.tanh(x @ y) @ y

    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    y = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(f).lower(x, y).compile()
    mine = analyze_hlo(c.as_text())
    assert mine.flops == pytest.approx(_xla_flops(c), rel=1e-6)


def test_hlo_cost_multiplies_scan_bodies():
    def g(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        return jax.lax.scan(body, x, None, length=16)[0]

    c = jax.jit(g).lower(jax.ShapeDtypeStruct((32, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    mine = analyze_hlo(c.as_text())
    assert mine.flops == pytest.approx(2 * 32 * 64 * 64 * 16, rel=1e-6)
    # XLA counts the body once (± the loop counter) — our reason for existing
    assert _xla_flops(c) == pytest.approx(2 * 32 * 64 * 64, rel=1e-3)


def test_hlo_cost_nested_scans():
    def nested(x, w):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            return jax.lax.scan(inner, h, None, length=4)[0], None
        return jax.lax.scan(outer, x, None, length=3)[0]

    c = jax.jit(nested).lower(
        jax.ShapeDtypeStruct((16, 32), jnp.float32),
        jax.ShapeDtypeStruct((32, 32), jnp.float32)).compile()
    assert analyze_hlo(c.as_text()).flops == pytest.approx(
        2 * 16 * 32 * 32 * 12, rel=1e-6)


def test_hlo_cost_counts_collectives_inside_scans():
    import functools
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("d",))

    @functools.partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
    def h(x):
        def body(carry, _):
            gathered = jax.lax.all_gather(carry, "d", tiled=True)
            return carry + gathered.reshape(1, -1).sum(0), None
        return jax.lax.scan(body, x, None, length=5)[0]

    with mesh:
        c = jax.jit(h).lower(
            jax.ShapeDtypeStruct((256,), jnp.float32)).compile()
    cost = analyze_hlo(c.as_text())
    assert cost.collective_bytes.get("all-gather", 0) == \
        pytest.approx(256 * 4 * 5)


# ---------------------------------------------------------------------------
# 1-D graph mesh (PR 9): shared cached mesh + placement specs
#
# These run on the ambient device pool — a single real CPU device is enough
# for the identity/spec assertions, and the multi-device legs execute for
# real under the `sharded-sim` CI lane's simulated 8-device host mesh
# instead of being skipped.
# ---------------------------------------------------------------------------


def test_graph_mesh_cached_identity_and_axis():
    from repro.launch.mesh import GRAPH_AXIS, graph_mesh
    m = graph_mesh(1)
    assert graph_mesh(1) is m          # lru-cached: identity keys jit caches
    assert m.axis_names == (GRAPH_AXIS,)
    assert m.devices.shape == (1,)


def test_graph_mesh_rejects_oversubscription():
    from repro.launch.mesh import graph_mesh
    with pytest.raises(ValueError, match="device"):
        graph_mesh(len(jax.devices()) + 1)


def test_graph_specs_place_arrays():
    from repro.launch.mesh import GRAPH_AXIS, graph_mesh
    from repro.launch.sharding import (graph_replicated_spec,
                                       graph_shard_spec)
    d = min(2, len(jax.devices()))
    if d < 2:
        pytest.skip("needs >= 2 devices (simulated host mesh); the "
                    "sharded-sim CI lane runs this leg")
    mesh = graph_mesh(d)
    sh = graph_shard_spec(mesh)
    rep = graph_replicated_spec(mesh)
    assert sh.spec == P(GRAPH_AXIS) and rep.spec == P()
    x = jax.device_put(jnp.arange(8, dtype=jnp.float32), sh)
    r = jax.device_put(jnp.arange(8, dtype=jnp.float32), rep)
    assert len(x.sharding.device_set) == d
    assert x.sharding.is_equivalent_to(sh, x.ndim)
    assert r.sharding.is_fully_replicated
    np.testing.assert_array_equal(np.asarray(x), np.arange(8))


def test_shard_plan_uses_shared_mesh():
    # the engine's per-shard plan family must ride the same cached mesh as
    # launch-layer consumers, or jit caches fragment per-mesh-object
    from repro.core.graph import Graph
    from repro.launch.mesh import graph_mesh
    g = Graph.from_edges(np.asarray([0, 1, 2], np.int32),
                         np.asarray([1, 2, 0], np.int32))
    sp = g.plan().sharded(1)
    assert sp.mesh is graph_mesh(1)


def test_runnable_vs_skip_matrix_documented():
    """Dry-run skip policy matches DESIGN §Arch-applicability."""
    from repro.configs.base import runnable_shapes, list_archs
    skip_long = {"whisper-small", "qwen1.5-4b", "qwen2.5-3b",
                 "starcoder2-15b", "mistral-nemo-12b", "grok-1-314b",
                 "qwen3-moe-235b-a22b", "internvl2-26b"}
    for arch in list_archs():
        if arch == "ringo-graph":
            continue
        has_long = "long_500k" in runnable_shapes(get_config(arch))
        assert has_long == (arch not in skip_long), arch
